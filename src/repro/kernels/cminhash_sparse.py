"""Sparse C-MinHash via contiguous window-mins (the fast signing path).

The gather formulation (``core.cminhash.cminhash_sparse``) computes
``h_q = min_j pi[(idx_j - q - off) mod D]`` with an O(B * nnz * K) random
gather into pi.  Reversing pi turns every hash index into a *contiguous*
window read:

    rev[m]      = pi[(D - 1 - m) mod D]
    s_j         = (D - 1 - idx_j + off) mod D
    h_q         = min_j rev_ext[s_j + q],      q = 0..K-1

where ``rev_ext`` is rev extended circularly by the window length.  Each
nonzero contributes one length-K contiguous slice of a VMEM/cache-resident
table, elementwise-min accumulated — scatter-free, gather-free, exactly the
layout a TPU VPU (and a CPU cache line) wants.  Invalid (padding) entries are
pointed at a SENTINEL region of the table, so no validity masking happens in
the hot loop.

Two implementations of the same scan share the precompute helpers:

* ``cminhash_sparse_windows`` — pure compiled jnp (vmapped dynamic slices);
  the dispatchable fast path on CPU and the oracle-equivalent of the kernel.
* ``cminhash_sparse_pallas`` — the Pallas kernel: grid over (batch tiles,
  nnz tiles), window table resident in VMEM, fori_loop of per-row dynamic
  slices min-folded into the output block.  On TPU the window length is
  padded to the 128-lane geometry; ``interpret=True`` runs it on CPU.

Both are bit-identical to the gather path (same exact integer mins), and both
take the same ``pack_b`` fused sign->pack epilogue as the dense kernels: the
Pallas kernel accumulates mins in VMEM scratch and packs b-bit words on the
last nnz tile (``packfmt.pack_block``), the jnp twin folds ``pack_codes``
into the same compiled scan — either way no (B, K) int32 crosses back as a
separate device step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .packfmt import pack_block, pack_codes, pack_geometry

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


def _check(d: int, k: int) -> None:
    if k > d:
        raise ValueError(f"C-MinHash requires K <= D (got K={k}, D={d})")


def window_table(pi: Array, wl: int, dtype=jnp.int32, sentinel=SENTINEL) -> Array:
    """(D,) pi -> (D + 2*wl - 1,) reversed/extended window table.

    Layout: ``t[m] = pi[(D - 1 - m) mod D]`` for ``m < D + wl - 1`` (circular
    extension so any valid start s < D can read a full wl-window), then wl
    ``sentinel`` entries.  ``invalid_start(d, wl)`` indexes a window that reads
    only sentinel — padding rows/columns resolve to the sentinel with zero
    branching in the scan.  ``sentinel`` must be >= every pi value so it can
    never win a min against real data.
    """
    d = pi.shape[0]
    rev = pi[::-1].astype(dtype)
    reps = -(-(d + wl - 1) // d)
    ext = jnp.tile(rev, reps)[: d + wl - 1]
    return jnp.concatenate([ext, jnp.full((wl,), sentinel, dtype)])


def invalid_start(d: int, wl: int) -> int:
    """Window start whose wl-window lies wholly in the SENTINEL region."""
    return d + wl - 1


def window_starts(idx: Array, d: int, wl: int, *, shift_offset: int) -> Array:
    """(B, NNZ) padded index lists -> (B, NNZ) int32 window starts.

    Valid entries map to ``(D - 1 - idx + off) mod D``; padding (< 0) maps to
    the SENTINEL window start.
    """
    s = (d - 1 - idx + shift_offset) % d
    return jnp.where(idx >= 0, s, invalid_start(d, wl)).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k", "shift_offset", "block_j", "pack_b"))
def cminhash_sparse_windows(idx: Array, pi: Array, k: int,
                            sigma: Array | None = None, *,
                            shift_offset: int = 1, block_j: int = 64,
                            pack_b: int | None = None) -> Array:
    """Compiled-jnp window-min scan: (B, NNZ) index lists -> (B, K) int32,
    or (B, ceil(K/(32/pack_b))) uint32 packed words when ``pack_b`` is set
    (the b-bit truncate+pack runs inside the same compiled scan).

    Same data movement as the Pallas kernel (contiguous slices of the window
    table, min-folded over nnz tiles of ``block_j``), expressed as vmapped
    ``dynamic_slice`` under ``lax.scan`` so XLA emits block copies instead of
    elementwise gathers.  This is the dispatchable fast path on CPU.

    Two details carry the speedup (profiled on CPU):

    * the per-tile fold is a *halving tree* of elementwise ``minimum`` over
      contiguous (B, jt/2, K) halves — ``jnp.min(axis=1)`` reduces along a
      stride-K axis and is several times slower than the whole gather;
    * when D <= 2^16 every pi value fits uint16, halving the table and fold
      traffic.  The uint16 sentinel (0xFFFF) is the max representable value,
      so it can never beat a real min — only rows with no valid index at all
      need the explicit SENTINEL fixup at the end.

    Results are bit-identical to the gather path in all cases.
    """
    d = pi.shape[0]
    _check(d, k)
    if sigma is not None:
        from ..core.permutations import apply_permutation_sparse
        idx = apply_permutation_sparse(idx, sigma)
    b, nnz = idx.shape
    narrow = d <= (1 << 16)
    dtype, sentinel = ((jnp.uint16, (1 << 16) - 1) if narrow
                       else (jnp.int32, SENTINEL))
    table = window_table(pi, k, dtype, sentinel)
    s = window_starts(idx, d, k, shift_offset=shift_offset)

    # power-of-two tile so the halving tree stays exact halves
    jt = 1 << max(0, min(block_j, nnz).bit_length() - 1)
    nj = -(-nnz // jt)
    if nj * jt != nnz:
        s = jnp.pad(s, ((0, 0), (0, nj * jt - nnz)),
                    constant_values=invalid_start(d, k))

    slice_one = lambda st: jax.lax.dynamic_slice(table, (st,), (k,))
    windows = jax.vmap(jax.vmap(slice_one))          # (B, jt) starts -> (B, jt, K)

    def step(acc, s_tile):                           # s_tile: (B, jt)
        w = windows(s_tile)
        while w.shape[1] > 1:                        # contiguous SIMD halves
            half = w.shape[1] // 2
            w = jnp.minimum(w[:, :half], w[:, half:])
        return jnp.minimum(acc, w[:, 0]), None

    acc0 = jnp.full((b, k), sentinel, dtype)
    s_tiles = s.reshape(b, nj, jt).transpose(1, 0, 2)
    acc, _ = jax.lax.scan(step, acc0, s_tiles)
    out = acc.astype(jnp.int32)
    if narrow:                    # empty rows: uint16 sentinel -> int32 one
        out = jnp.where((idx >= 0).any(axis=1)[:, None], out, SENTINEL)
    return out if pack_b is None else pack_codes(out, pack_b)


def _kernel(table_ref, s_ref, out_ref, acc_scratch=None, *, bt: int, jt: int,
            wl: int, nj: int = 0, k: int = 0, pack_b: int | None = None):
    # fused pack accumulates mins in VMEM scratch, packing on the last tile
    # (see cminhash_packed._kernel — same epilogue contract)
    acc_ref = out_ref if pack_b is None else acc_scratch

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, SENTINEL, acc_ref.dtype)

    table = table_ref[...]                            # (L,) int32
    sv = s_ref[...]                                   # (bt, jt) int32

    def body(jl, acc):
        col = jax.lax.dynamic_slice(sv, (0, jl), (bt, 1))[:, 0]
        win = jnp.stack([
            jax.lax.dynamic_slice(table, (col[bl],), (wl,))
            for bl in range(bt)])                     # (bt, wl)
        return jnp.minimum(acc, win)

    acc_ref[...] = jax.lax.fori_loop(0, jt, body, acc_ref[...])

    if pack_b is not None:
        @pl.when(pl.program_id(1) == nj - 1)
        def _pack():
            out_ref[...] = pack_block(acc_ref[...], 0, k=k, b=pack_b)


@functools.partial(
    jax.jit,
    static_argnames=("k", "shift_offset", "block_b", "block_j", "interpret",
                     "pack_b"),
)
def cminhash_sparse_pallas(idx: Array, pi: Array, k: int, *,
                           shift_offset: int = 1, block_b: int = 8,
                           block_j: int = 32, interpret: bool = True,
                           pack_b: int | None = None) -> Array:
    """Sparse C-MinHash signatures via the tiled Pallas window-min kernel.

    idx: (B, NNZ) padded index lists (entries < 0 are padding), already
    sigma-permuted by the caller; pi: (D,) int32.  Returns (B, K) int32, or
    (B, ceil(K/(32/pack_b))) uint32 words from the fused truncate+pack
    epilogue when ``pack_b`` is set.

    Tiling: grid (batch tiles, nnz tiles); the window table is one
    VMEM-resident block (D + 2*Kp words — ~0.5 MB at D = 65536, K = 1024), so
    the only HBM traffic per tile is the (Bt, Jt) start block and the output
    min-accumulation; all K circulant shifts come from that single resident
    table.  Window length is padded to the 128-lane geometry.
    """
    if shift_offset not in (0, 1):
        raise ValueError("shift_offset must be 0 or 1")
    d = pi.shape[0]
    _check(d, k)
    b, nnz = idx.shape
    bt = max(1, block_b)
    jt = max(1, block_j)
    wl = -(-k // 128) * 128                           # lane-padded window
    nb, nj = -(-b // bt), -(-nnz // jt)

    table = window_table(pi, wl)
    lp = -(-table.shape[0] // 128) * 128
    if lp != table.shape[0]:                          # lane-pad; values unread
        table = jnp.pad(table, (0, lp - table.shape[0]),
                        constant_values=SENTINEL)

    s0 = invalid_start(d, wl)
    s = jnp.full((nb * bt, nj * jt), s0, jnp.int32)
    s = s.at[:b, :nnz].set(window_starts(idx, d, wl,
                                         shift_offset=shift_offset))

    in_specs = [
        pl.BlockSpec((lp,), lambda i, j: (0,)),
        pl.BlockSpec((bt, jt), lambda i, j: (i, j)),
    ]
    if pack_b is None:
        out = pl.pallas_call(
            functools.partial(_kernel, bt=bt, jt=jt, wl=wl),
            grid=(nb, nj), in_specs=in_specs,
            out_specs=pl.BlockSpec((bt, wl), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((nb * bt, wl), jnp.int32),
            interpret=interpret,
        )(table, s)
        return out[:b, :k]

    cpw, n_words = pack_geometry(k, pack_b)   # wl % cpw == 0: wl % 128 == 0
    owords = pl.pallas_call(
        functools.partial(_kernel, bt=bt, jt=jt, wl=wl, nj=nj, k=k,
                          pack_b=pack_b),
        grid=(nb, nj), in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, wl // cpw), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * bt, wl // cpw), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bt, wl), jnp.int32)],
        interpret=interpret,
    )(table, s)
    return owords[:b, :n_words]
