"""Pallas TPU kernel for pairwise signature collision counting (search hot loop).

count[q, n] = sum_k 1{sig_q[q, k] == sig_n[n, k]} — an "equality matmul": the data
flow is exactly a (Q, K) x (K, N) contraction with (==, +) instead of (*, +), so the
same VMEM tiling that feeds the MXU feeds the VPU here.  Estimated Jaccard is
count / K (estimators.pairwise_jaccard_from_signatures is the oracle).

K-padding uses distinct sentinels per side so padded columns can never match.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(q_ref, n_ref, out_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    qs = q_ref[...]  # (Qt, Kt)
    ns = n_ref[...]  # (Nt, Kt)
    eq = (qs[:, None, :] == ns[None, :, :]).astype(jnp.int32)
    out_ref[...] += jnp.sum(eq, axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_n", "block_k", "interpret"))
def collision_count_pallas(sig_q: Array, sig_n: Array, *, block_q: int = 64,
                           block_n: int = 64, block_k: int = 128,
                           interpret: bool = True) -> Array:
    """(Q, K) x (N, K) int32 signatures -> (Q, N) int32 match counts."""
    q, k = sig_q.shape
    n, k2 = sig_n.shape
    if k != k2:
        raise ValueError(f"signature widths differ: {k} vs {k2}")
    qt, nt, kt = block_q, block_n, block_k
    nq, nn, nk = -(-q // qt), -(-n // nt), -(-k // kt)

    qp = jnp.full((nq * qt, nk * kt), -1, jnp.int32).at[:q, :k].set(sig_q)
    np_ = jnp.full((nn * nt, nk * kt), -2, jnp.int32).at[:n, :k].set(sig_n)

    out = pl.pallas_call(
        _kernel,
        grid=(nq, nn, nk),
        in_specs=[
            pl.BlockSpec((qt, kt), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((nt, kt), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((qt, nt), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nq * qt, nn * nt), jnp.int32),
        interpret=interpret,
    )(qp, np_)
    return out[:q, :n]
