"""Periodic JSONL metrics/span dumps, and a checker for CI smoke.

``MetricsDumper`` is a daemon thread that appends one JSON object per
interval to a file::

    {"t": <unix time>, "seq": <n>, "metrics": <registry snapshot>,
     "spans": [<finished span dicts>...]}

Snapshots are cumulative (each line is the registry's full state at that
instant); spans are incremental (each line drains the tracer's ring, so a
span appears on exactly one line).  A final line is always written on
``close()`` so short-lived runs still leave a complete record.

An optional ``extra`` callable contributes per-line fields — the serve
driver uses it to fold in worker STATS snapshots so one dump file covers
the whole plane.

``check_dump`` (also ``python -m repro.obs.dump --check PATH``) validates
a dump file: every line parses, has the schema above, and — with
``--require-shard-hists`` — at least one snapshot carries a nonzero
per-shard partial-latency histogram (the CI metrics-smoke gate).
``--require-overload`` additionally requires the overload-hardening
families to be wired: the plane's shared retry-budget token gauge, at
least one per-lane circuit-breaker state gauge, and at least one
shedding-surface metric (streaming admission queue or worker admission
gate).  Names are matched as substrings so per-lane relabelled worker
snapshots (``shard0.replica1.worker.overloaded``) count.
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time

from . import metrics as _metrics
from . import trace as _trace


class MetricsDumper:
    """Append registry snapshots + drained spans to ``path`` every
    ``interval_s`` seconds until closed."""

    def __init__(self, path: str, interval_s: float = 1.0,
                 registry=None, tracer=None, extra=None):
        self.path = path
        self.interval_s = max(0.05, float(interval_s))
        self._registry = registry
        self._tracer = tracer
        self._extra = extra
        self._seq = 0
        self._stop = threading.Event()
        self._f = open(path, "a", encoding="utf-8")
        self._lock = threading.Lock()
        self._thread = threading.Thread(
            target=self._run, name="metrics-dump", daemon=True)
        self._thread.start()

    def _write_line(self) -> None:
        reg = self._registry or _metrics.default()
        tr = self._tracer or _trace.default()
        line = {"t": time.time(), "seq": self._seq,
                "metrics": reg.snapshot(), "spans": tr.drain()}
        if self._extra is not None:
            try:
                line.update(self._extra() or {})
            except Exception as e:          # never let a stats fetch kill dumps
                line["extra_error"] = repr(e)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(json.dumps(line) + "\n")
            self._f.flush()
        self._seq += 1

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write_line()

    def close(self) -> None:
        """Stop the thread and write one final line."""
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._write_line()
        with self._lock:
            self._f.close()

    def __enter__(self) -> "MetricsDumper":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# the overload-hardening metric surface, grouped by what must exist for
# the plane to be considered wired (substring match against metric names,
# so per-lane relabelled worker snapshots count)
_OVERLOAD_FAMILIES = {
    "retry_budget": ("transport.retry_budget.tokens",),
    "breaker": ("transport.breaker.",),
    "shed_surface": ("stream.queue_depth", "stream.shed",
                     "worker.admission.depth", "worker.overloaded"),
}


def _iter_snapshots(line: dict):
    """The line's own registry snapshot plus any snapshot-shaped dicts an
    ``extra`` callable folded in (worker STATS obs payloads)."""
    yield line["metrics"]
    for key, val in line.items():
        if key == "metrics" or not isinstance(val, dict):
            continue
        if {"counters", "gauges", "hists"} <= set(val):
            yield val
        else:
            for sub in val.values():
                if isinstance(sub, dict) \
                        and {"counters", "gauges", "hists"} <= set(sub):
                    yield sub


def check_dump(path: str, require_shard_hists: bool = False,
               require_overload: bool = False) -> dict:
    """Validate a dump file; raise ``ValueError`` on malformed content.

    Returns summary stats: line count, span count, the per-shard
    partial-latency histogram names seen with nonzero counts, and which
    overload-hardening metric families were present.
    """
    n_lines = 0
    n_spans = 0
    shard_hists: set[str] = set()
    overload_seen: dict[str, set] = {k: set() for k in _OVERLOAD_FAMILIES}
    with open(path, encoding="utf-8") as f:
        for lineno, raw in enumerate(f, 1):
            raw = raw.strip()
            if not raw:
                continue
            try:
                line = json.loads(raw)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not JSON: {e}") from None
            for key in ("t", "seq", "metrics", "spans"):
                if key not in line:
                    raise ValueError(f"{path}:{lineno}: missing {key!r}")
            snap = line["metrics"]
            for key in ("counters", "gauges", "hists"):
                if key not in snap:
                    raise ValueError(
                        f"{path}:{lineno}: snapshot missing {key!r}")
            for name, h in snap["hists"].items():
                if not isinstance(h.get("count"), int):
                    raise ValueError(
                        f"{path}:{lineno}: hist {name!r} has no int count")
                if ".shard" in name and ".partial" in name and h["count"] > 0:
                    shard_hists.add(name)
            for sub in _iter_snapshots(line):
                names = list(sub.get("counters", {})) \
                    + list(sub.get("gauges", {}))
                for family, needles in _OVERLOAD_FAMILIES.items():
                    for name in names:
                        if any(n in name for n in needles):
                            overload_seen[family].add(name)
            n_spans += len(line["spans"])
            n_lines += 1
    if n_lines == 0:
        raise ValueError(f"{path}: empty dump")
    if require_shard_hists and len(shard_hists) < 2:
        raise ValueError(
            f"{path}: expected nonzero per-shard partial histograms for >=2 "
            f"shards, saw {sorted(shard_hists)}")
    if require_overload:
        missing = [f for f, seen in overload_seen.items() if not seen]
        if missing:
            raise ValueError(
                f"{path}: overload metric families missing: {missing} "
                f"(need {[_OVERLOAD_FAMILIES[f] for f in missing]})")
    return {"lines": n_lines, "spans": n_spans,
            "shard_hists": sorted(shard_hists),
            "overload_families": {k: sorted(v)
                                  for k, v in overload_seen.items() if v}}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dump", description=check_dump.__doc__)
    ap.add_argument("--check", metavar="PATH", required=True,
                    help="dump file to validate")
    ap.add_argument("--require-shard-hists", action="store_true",
                    help="require nonzero per-shard partial histograms "
                         "from >=2 shards (CI smoke gate)")
    ap.add_argument("--require-overload", action="store_true",
                    help="require the overload-hardening metric families "
                         "(retry budget, circuit breakers, a shedding "
                         "surface) to appear in the dump (CI smoke gate)")
    args = ap.parse_args(argv)
    try:
        out = check_dump(args.check,
                         require_shard_hists=args.require_shard_hists,
                         require_overload=args.require_overload)
    except (OSError, ValueError) as e:
        print(f"FAIL: {e}", file=sys.stderr)
        return 1
    print(f"OK: {out['lines']} lines, {out['spans']} spans, "
          f"shard hists: {out['shard_hists']}, "
          f"overload families: {sorted(out['overload_families'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
