"""Zero-dependency observability plane: mergeable metrics + wire traces.

``repro.obs.metrics``  — counters, gauges, fixed-log-bucket histograms
with exact (integer) merges, a process registry, and snapshot algebra.
``repro.obs.trace``    — sampled spans with coordinator->worker id
propagation over the existing frame protocol.
``repro.obs.dump``     — periodic JSONL dumps + a CI checker.

See README.md in this directory for the model and merge semantics.
"""

from .metrics import (Counter, Gauge, Histogram, Registry, NULL,
                      default, set_default, empty_snapshot,
                      merge_snapshots, snapshot_delta, hist_quantile,
                      hist_sum)
from .trace import (TraceCtx, Span, Tracer, NULL_SPAN)
from . import metrics, trace

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "NULL",
    "default", "set_default", "empty_snapshot", "merge_snapshots",
    "snapshot_delta", "hist_quantile", "hist_sum",
    "TraceCtx", "Span", "Tracer", "NULL_SPAN",
    "metrics", "trace",
]
