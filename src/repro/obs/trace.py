"""Lightweight cross-process trace spans for the sign -> shard -> serve path.

A trace is a 63-bit id shared by every span of one logical operation (one
query batch, one ingest scatter).  Spans carry (trace_id, span_id,
parent_id, proc, start, duration, tags) and are recorded into a bounded
ring on the process-local ``Tracer``; completed spans are plain dicts, so
they serialize to JSON and travel the wire unchanged.

Sampling happens ONCE, at the root: ``Tracer.span(name)`` with no ambient
parent rolls ``sample_rate``; an unsampled root returns the shared no-op
span and every descendant (local or remote) inherits the decision for
free.  Sampled spans push themselves onto a thread-local ambient stack, so
nested instrumentation (service -> sharded store -> fan-out) stitches
parent/child without threading a context argument through every call.

Cross-process propagation rides the transport's existing request/reply
pairing: the coordinator attaches ``ctx()`` (trace id + parent span id) as
two int fields on the request frame, the worker opens its spans under that
parent, and the reply echoes the worker's finished spans back as a JSON
field next to the echoed seq — ``Tracer.absorb`` folds them into the
coordinator's ring, producing one stitched trace (``for_trace``).
"""

from __future__ import annotations

import collections
import json
import random
import threading
import time
from typing import NamedTuple


class TraceCtx(NamedTuple):
    """What crosses a process boundary: the trace and the parent span."""

    trace_id: int
    span_id: int


def _new_id() -> int:
    return random.getrandbits(63) or 1


class Span:
    """One timed leg.  Use as a context manager; on exit it records itself
    into its tracer's finished ring."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "proc",
                 "t_start", "_t0", "dur_s", "tags", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, trace_id: int,
                 parent_id: int | None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = _new_id()
        self.parent_id = parent_id
        self.proc = tracer.proc
        self.tags: dict = {}
        self._tracer = tracer
        self.t_start = time.time()
        self._t0 = time.perf_counter()
        self.dur_s = 0.0

    sampled = True

    def tag(self, key: str, value) -> "Span":
        self.tags[key] = value
        return self

    def ctx(self) -> TraceCtx:
        return TraceCtx(self.trace_id, self.span_id)

    def to_dict(self) -> dict:
        return {"name": self.name, "trace": self.trace_id,
                "span": self.span_id, "parent": self.parent_id,
                "proc": self.proc, "t0": self.t_start, "dur_s": self.dur_s,
                "tags": self.tags}

    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, *exc) -> None:
        self.dur_s = time.perf_counter() - self._t0
        self._tracer._pop(self)


class _NullSpan:
    """Shared no-op span: the unsampled (and disabled-tracer) fast path."""

    sampled = False
    trace_id = span_id = 0
    parent_id = None
    tags: dict = {}

    def tag(self, key: str, value) -> "_NullSpan":
        return self

    def ctx(self) -> None:
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Process-local span factory + finished-span ring.

    ``proc`` labels which process a span ran in (coordinator vs shard
    worker) so a stitched trace reads unambiguously.
    """

    def __init__(self, sample_rate: float = 0.0, proc: str = "main",
                 max_finished: int = 8192):
        self.sample_rate = float(sample_rate)
        self.proc = proc
        self.finished: collections.deque = collections.deque(
            maxlen=max_finished)
        self._local = threading.local()
        self._lock = threading.Lock()

    # -- ambient stack -------------------------------------------------------
    def _stack(self) -> list:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        else:                       # out-of-order exit: drop it wherever it is
            try:
                stack.remove(span)
            except ValueError:
                pass
        with self._lock:
            self.finished.append(span.to_dict())

    def current(self) -> TraceCtx | None:
        """The ambient trace context (what remote submits put on the wire)."""
        stack = self._stack()
        return stack[-1].ctx() if stack else None

    # -- span creation -------------------------------------------------------
    def span(self, name: str, parent: TraceCtx | None = None):
        """Open a span.  Explicit ``parent`` (a wire-propagated ctx) always
        samples; otherwise nest under the ambient span; otherwise this is a
        root — roll ``sample_rate``."""
        if parent is not None:
            return Span(self, name, parent.trace_id, parent.span_id)
        ambient = self.current()
        if ambient is not None:
            return Span(self, name, ambient.trace_id, ambient.span_id)
        if self.sample_rate <= 0.0 or random.random() >= self.sample_rate:
            return NULL_SPAN
        return Span(self, name, _new_id(), None)

    # -- finished spans ------------------------------------------------------
    def absorb(self, spans) -> None:
        """Fold remote span dicts (a worker reply's echo) into the ring."""
        with self._lock:
            self.finished.extend(spans)

    def absorb_json(self, blob: str | None) -> None:
        if blob:
            self.absorb(json.loads(blob))

    def drain(self) -> list[dict]:
        """Pop every finished span (what replies/dumps ship)."""
        with self._lock:
            out = list(self.finished)
            self.finished.clear()
        return out

    def for_trace(self, trace_id: int) -> list[dict]:
        """All finished spans of one trace (non-destructive)."""
        with self._lock:
            return [s for s in self.finished if s.get("trace") == trace_id]

    def last_trace_id(self) -> int | None:
        with self._lock:
            for s in reversed(self.finished):
                if s.get("parent") is None:
                    return s.get("trace")
            return self.finished[-1].get("trace") if self.finished else None


_default = Tracer()


def default() -> Tracer:
    """The process-wide tracer (workers get their own per process)."""
    return _default


def set_default(tracer: Tracer) -> Tracer:
    global _default
    old, _default = _default, tracer
    return old


def current() -> TraceCtx | None:
    """Ambient trace context of the default tracer (the wire-injection
    hook: remote backends call this at submit time)."""
    return _default.current()
