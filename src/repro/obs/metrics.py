"""Zero-dependency metrics registry: counters, gauges, log-bucket histograms.

Every instrument lives in a ``Registry`` keyed by name.  The design contract
is the same one ``distributed.collectives.merge_topk`` gives the serving
plane: per-process (per-shard, per-worker) measurements reduce to a global
view with an **exact, associative, commutative** merge — so S shard
snapshots can be combined in any order, in any grouping, and produce the
same bytes.

  * ``Counter``   — monotonically increasing int.  Merge: integer add.
  * ``Gauge``     — a level (occupancy, slots, queue depth).  Merge: sum —
    gauges are chosen to be summable across shards (used slots, items,
    bytes), not ratios; derive ratios after merging.
  * ``Histogram`` — fixed log-spaced buckets shared by every histogram in
    the system, so the merge is an elementwise bucket add.  Observations
    are quantized to 1e-9 (int "nanos") before summing, which makes
    ``sum`` an integer and the whole merge bit-exact regardless of merge
    order — float accumulation order can never make two reduction trees
    disagree.

Bucket layout (module constants, identical in every process): bucket 0 is
the underflow (< ``HIST_MIN``), then ``HIST_BUCKETS_PER_DOUBLING`` buckets
per doubling for ``HIST_DOUBLINGS`` doublings, then one overflow bucket.
With the defaults that resolves 1 us .. ~1073 s at ~19% relative error —
enough for p50/p90/p99 on every latency in the plane, in 122 int64s.

Snapshots are plain JSON-able dicts (``snapshot()``), merged with
``merge_snapshots`` and diffed with ``snapshot_delta`` (how a benchmark
scopes percentiles to one timed block).  ``hist_quantile`` reads pXX off a
snapshot histogram.

The disabled fast path: ``Registry(enabled=False)`` (and the module
``NULL`` registry) hands out shared no-op singletons, so instrumented code
pays one attribute lookup + one empty call per event — the <1% overhead
contract ``bench_search`` tracks.  Set ``REPRO_OBS=0`` to boot the default
registry disabled (the env var propagates to spawned shard workers).
"""

from __future__ import annotations

import math
import os
import threading

# -- shared histogram layout --------------------------------------------------

HIST_MIN = 1e-6                     # smallest resolvable value (1 us)
HIST_BUCKETS_PER_DOUBLING = 4       # ~19% relative bucket width
HIST_DOUBLINGS = 30                 # HIST_MIN .. HIST_MIN * 2**30 (~1073 s)
N_LOG_BUCKETS = HIST_BUCKETS_PER_DOUBLING * HIST_DOUBLINGS
N_BUCKETS = N_LOG_BUCKETS + 2       # + underflow (index 0) + overflow (last)

_QUANT = 1e9                        # observations summed as int "nanos"


def bucket_index(v: float) -> int:
    """Value -> bucket index (0 = underflow, N_BUCKETS-1 = overflow)."""
    if v < HIST_MIN:
        return 0
    i = 1 + int(math.log2(v / HIST_MIN) * HIST_BUCKETS_PER_DOUBLING)
    return i if i < N_BUCKETS - 1 else N_BUCKETS - 1


def bucket_bounds(i: int) -> tuple[float, float]:
    """Bucket index -> [lo, hi) value bounds."""
    if i <= 0:
        return 0.0, HIST_MIN
    if i >= N_BUCKETS - 1:
        return HIST_MIN * 2.0 ** (N_LOG_BUCKETS / HIST_BUCKETS_PER_DOUBLING), \
            math.inf
    step = 1.0 / HIST_BUCKETS_PER_DOUBLING
    return HIST_MIN * 2.0 ** ((i - 1) * step), HIST_MIN * 2.0 ** (i * step)


def _bucket_mid(i: int) -> float:
    """Representative value for a bucket (geometric midpoint)."""
    lo, hi = bucket_bounds(i)
    if i <= 0:
        return HIST_MIN / 2.0
    if i >= N_BUCKETS - 1:
        return lo
    return math.sqrt(lo * hi)


# -- instruments --------------------------------------------------------------

class Counter:
    """Monotonic event count."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """A level.  ``add`` deltas keep multi-instance gauges summable: N
    tables in one process each add (new - previously_reported), so the
    gauge reads the in-process total, mirroring the cross-process sum
    merge."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, dv: float) -> None:
        self.value += float(dv)


class Histogram:
    """Fixed-log-bucket latency/value histogram with exact merge.

    ``last`` is a live-object convenience (the most recent observation —
    what ``ShardedSketchStore.last_timings`` renders); it is NOT part of
    snapshots, which carry only the exactly-mergeable state.
    """

    def __init__(self, name: str):
        self.name = name
        self.counts = [0] * N_BUCKETS
        self.count = 0
        self.sum_q = 0              # sum of observations, int 1e-9 units
        self.vmin: float | None = None
        self.vmax: float | None = None
        self.last = 0.0
        # observe_n callers (probe-depth style) feed a handful of repeated
        # small values; memoize value -> (bucket, quantized) so the hot
        # loop skips the log2 + round.  Bounded; latency-style observe()
        # never touches it (distinct floats would only churn the dict).
        self._memo: dict[float, tuple[int, int]] = {}

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bucket_index(v)] += 1
        self.count += 1
        self.sum_q += int(round(v * _QUANT))
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v
        self.last = v

    def observe_n(self, v: float, n: int) -> None:
        """Record ``n`` identical observations (batched paths: e.g. "k
        probe chains terminated at depth t")."""
        if n <= 0:
            return
        v = float(v)
        ent = self._memo.get(v)
        if ent is None:
            if len(self._memo) >= 256:
                self._memo.clear()
            ent = self._memo[v] = (bucket_index(v), int(round(v * _QUANT)))
        self.counts[ent[0]] += n
        self.count += n
        self.sum_q += n * ent[1]
        if self.vmin is None or v < self.vmin:
            self.vmin = v
        if self.vmax is None or v > self.vmax:
            self.vmax = v
        self.last = v

    @property
    def sum(self) -> float:
        return self.sum_q / _QUANT

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        return _quantile_from_counts(self.counts, self.count, q)

    def to_snapshot(self) -> dict:
        return {"count": self.count, "sum_ns": self.sum_q,
                "min": self.vmin, "max": self.vmax,
                "buckets": {str(i): c for i, c in enumerate(self.counts)
                            if c}}


def _quantile_from_counts(counts, total: int, q: float) -> float:
    """Quantile with WITHIN-bucket interpolation.

    Bucket-edge-only reporting made p50==p90==p99 whenever one log bucket
    held most of the mass (every small-N latency stage) — three identical
    numbers that look like a measurement but carry one bucket's worth of
    information.  Instead, locate the bucket holding rank ``q * total`` and
    place the quantile at the fractional rank within it: geometrically for
    log buckets (constant relative width), linearly for the underflow bucket
    (starts at 0), and at the lower edge for the unbounded overflow bucket.
    Still bucket-limited (~19% relative), but distinct quantiles now move
    apart whenever their ranks differ; pair with the sample count (callers
    report ``n``) so small-N percentiles read as what they are.
    """
    if total <= 0:
        return 0.0
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1] (got {q})")
    want = q * total
    seen = 0
    if isinstance(counts, dict):
        items = sorted((int(i), c) for i, c in counts.items())
    else:
        items = [(i, c) for i, c in enumerate(counts) if c]
    for i, c in items:
        if seen + c >= want:
            f = min(max((want - seen) / c, 0.0), 1.0)
            lo, hi = bucket_bounds(i)
            if i >= N_BUCKETS - 1:
                return lo                     # overflow: unbounded above
            if lo <= 0.0:
                return hi * f                 # underflow: linear from 0
            return lo * (hi / lo) ** f        # log bucket: geometric
        seen += c
    return _bucket_mid(items[-1][0]) if items else 0.0


# -- no-op twins (the disabled fast path) -------------------------------------

class _NullCounter:
    name = ""
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    name = ""
    value = 0.0

    def set(self, v: float) -> None:
        pass

    def add(self, dv: float) -> None:
        pass


class _NullHistogram:
    name = ""
    count = 0
    sum_q = 0
    sum = 0.0
    mean = 0.0
    last = 0.0
    vmin = vmax = None

    def observe(self, v: float) -> None:
        pass

    def observe_n(self, v: float, n: int) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0

    def to_snapshot(self) -> dict:
        return {"count": 0, "sum_ns": 0, "min": None, "max": None,
                "buckets": {}}


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


# -- the registry -------------------------------------------------------------

class Registry:
    """Named instruments + snapshot/merge.  Instrument creation is locked
    (the dump thread may race a first-use); reads are lock-free — a
    snapshot taken mid-update is merely a moment older, never corrupt."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def _get(self, table: dict, name: str, cls):
        got = table.get(name)
        if got is None:
            with self._lock:
                got = table.setdefault(name, cls(name))
        return got

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        return self._get(self._counters, name, Counter)

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        return self._get(self._gauges, name, Gauge)

    def histogram(self, name: str) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        return self._get(self._hists, name, Histogram)

    def snapshot(self) -> dict:
        """JSON-able state: {"counters": {...}, "gauges": {...},
        "hists": {name: {count, sum_ns, min, max, buckets}}}."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "hists": {n: h.to_snapshot() for n, h in self._hists.items()},
        }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


NULL = Registry(enabled=False)

_ENV = "REPRO_OBS"
_default: Registry = NULL if os.environ.get(_ENV, "") == "0" else Registry()


def default() -> Registry:
    """The process-wide registry (instrument handles are cached at
    component construction, so swap BEFORE building the plane)."""
    return _default


def set_default(reg: Registry) -> Registry:
    """Swap the default registry; returns the previous one."""
    global _default
    old, _default = _default, reg
    return old


# -- snapshot algebra ---------------------------------------------------------

def empty_snapshot() -> dict:
    return {"counters": {}, "gauges": {}, "hists": {}}


def _merge_hist(a: dict, b: dict) -> dict:
    buckets = dict(a.get("buckets", {}))
    for i, c in b.get("buckets", {}).items():
        buckets[i] = buckets.get(i, 0) + c
    mins = [m for m in (a.get("min"), b.get("min")) if m is not None]
    maxs = [m for m in (a.get("max"), b.get("max")) if m is not None]
    return {"count": a.get("count", 0) + b.get("count", 0),
            "sum_ns": a.get("sum_ns", 0) + b.get("sum_ns", 0),
            "min": min(mins) if mins else None,
            "max": max(maxs) if maxs else None,
            "buckets": buckets}


def merge_snapshots(*snaps: dict) -> dict:
    """Associative, commutative reduction of registry snapshots — counters
    and histogram state add exactly (ints), gauges sum.  Merging S shard
    snapshots in any grouping/order yields identical results, the same
    contract ``merge_topk`` gives partial top-ks."""
    out = empty_snapshot()
    for s in snaps:
        for n, v in s.get("counters", {}).items():
            out["counters"][n] = out["counters"].get(n, 0) + v
        for n, v in s.get("gauges", {}).items():
            out["gauges"][n] = out["gauges"].get(n, 0) + v
        for n, h in s.get("hists", {}).items():
            out["hists"][n] = _merge_hist(
                out["hists"].get(n) or {"count": 0, "sum_ns": 0,
                                        "min": None, "max": None,
                                        "buckets": {}}, h)
    return out


def label_snapshot(snap: dict, prefix: str) -> dict:
    """A copy of ``snap`` with every instrument name prefixed — how a
    plane snapshot keeps per-worker provenance: each worker's registry is
    merged twice, once raw (so plane-wide totals stay one series) and once
    under its ``shard{i}.replica{r}.`` prefix (so a failover
    investigation can see WHICH lane's counters moved).  Values are
    shared, not copied — treat the result as read-only merge input."""
    return {
        "counters": {prefix + n: v
                     for n, v in snap.get("counters", {}).items()},
        "gauges": {prefix + n: v
                   for n, v in snap.get("gauges", {}).items()},
        "hists": {prefix + n: h
                  for n, h in snap.get("hists", {}).items()},
    }


def snapshot_delta(before: dict, after: dict) -> dict:
    """What happened between two snapshots of the SAME registry: counters
    and histogram buckets subtract; gauges are levels, so the delta keeps
    ``after``'s values."""
    out = empty_snapshot()
    for n, v in after.get("counters", {}).items():
        d = v - before.get("counters", {}).get(n, 0)
        if d:
            out["counters"][n] = d
    out["gauges"] = dict(after.get("gauges", {}))
    for n, h in after.get("hists", {}).items():
        b = before.get("hists", {}).get(n)
        if b is None:
            out["hists"][n] = h
            continue
        buckets = {i: c - b.get("buckets", {}).get(i, 0)
                   for i, c in h.get("buckets", {}).items()
                   if c - b.get("buckets", {}).get(i, 0)}
        cnt = h.get("count", 0) - b.get("count", 0)
        if cnt or buckets:
            out["hists"][n] = {"count": cnt,
                               "sum_ns": h.get("sum_ns", 0) -
                               b.get("sum_ns", 0),
                               "min": h.get("min"), "max": h.get("max"),
                               "buckets": buckets}
    return out


def hist_quantile(h: dict, q: float) -> float:
    """pXX from a snapshot histogram (within-bucket interpolated; still
    bucket-limited to ~19% rel. err — report ``h["count"]`` alongside)."""
    return _quantile_from_counts(h.get("buckets", {}), h.get("count", 0), q)


def hist_sum(h: dict) -> float:
    return h.get("sum_ns", 0) / _QUANT
